//! Differential harness for the message-passing transport layer
//! (`lead::transport`).
//!
//! Pins the contract from `transport` §Transport contract and
//! `coordinator::engine` §Transport:
//!
//! 1. **Lossless ⇒ bitwise-invisible**: a fault-free run over the
//!    `channel` and `mux:<N>` backends reproduces the shared-memory
//!    reference trajectory bit for bit — dist/consensus/comp_err series
//!    and the per-round bits accounting — across algorithms (compressed
//!    and not), wire-complete codec families, topologies, engine thread
//!    counts, and multiplex widths.
//! 2. **Determinism**: transported runs are bitwise-identical across
//!    reruns and across thread counts, frame counters included.
//! 3. **Accounting**: `frames_sent` is exactly rounds × directed edges,
//!    nothing is dropped without faults, and `bytes_on_wire` counts the
//!    real framed envelopes (≥ header size per frame).
//! 4. **Multiplexing**: N-agents-per-worker slots host more agents than
//!    pool workers without changing a single bit.

use lead::algorithms::{choco::ChocoSgd, dgd::Dgd, lead::Lead, Algorithm};
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::topk::TopK;
use lead::compress::Compressor;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::coordinator::metrics::RunRecord;
use lead::problems::linreg::LinReg;
use lead::problems::Problem;
use lead::topology::{MixingRule, Topology};
use lead::transport::{frame, TransportMode};
use std::sync::Arc;

fn algo(name: &str) -> Box<dyn Algorithm> {
    match name {
        "lead" => Box::new(Lead::paper_default()),
        "choco" => Box::new(ChocoSgd::new(0.8)),
        "dgd" => Box::new(Dgd::new()),
        other => panic!("unknown test algo {other:?}"),
    }
}

fn codec(name: &str) -> Option<Box<dyn Compressor>> {
    match name {
        "topk" => Some(Box::new(TopK::new(10))),
        "qinf" => Some(Box::new(QuantizeP::new(2, PNorm::Inf, 64))),
        other => panic!("unknown test codec {other:?}"),
    }
}

fn topo(name: &str) -> Topology {
    match name {
        "ring" => Topology::Ring,
        "er" => Topology::ErdosRenyi { p: 0.5, seed: 17 },
        other => panic!("unknown test topology {other:?}"),
    }
}

/// One short run on the Fig. 1-shaped synthetic linreg workload over the
/// given transport mode.
fn run(
    algo_name: &str,
    codec_name: &str,
    topo_name: &str,
    transport: TransportMode,
    threads: usize,
    rounds: usize,
) -> RunRecord {
    let n = 8;
    let p = LinReg::synthetic(n, 30, 0.1, 3);
    let mix = topo(topo_name).build(n, MixingRule::UniformNeighbors);
    let cfg = EngineConfig { threads, record_every: 3, transport, ..Default::default() };
    let mut e = Engine::new(cfg, mix, Arc::new(p));
    e.run(algo(algo_name), codec(codec_name), rounds)
}

/// Directed edge count of a test topology (per-round frame count).
fn directed_edges(topo_name: &str) -> u64 {
    let mix = topo(topo_name).build(8, MixingRule::UniformNeighbors);
    (0..mix.n).map(|i| mix.neighbors[i].len() as u64).sum()
}

fn assert_series_bitwise(a: &RunRecord, b: &RunRecord, tag: &str) {
    assert_eq!(a.series.len(), b.series.len(), "{tag}: series length");
    for (ma, mb) in a.series.iter().zip(&b.series) {
        assert_eq!(ma.round, mb.round, "{tag}");
        assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.consensus.to_bits(), mb.consensus.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.comp_err.to_bits(), mb.comp_err.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.bits_per_agent, mb.bits_per_agent, "{tag} round {}", ma.round);
    }
}

/// Acceptance pin, rule 1: the full {algo} × {codec} × {topology} ×
/// {threads} × {layout} sweep is bitwise-invisible relative to the
/// shared-memory reference, with exact frame accounting on every cell.
/// (dgd ignores the codec — `AlgoSpec::compressed` is false — so its
/// cells exercise the raw-frame path.)
#[test]
fn lossless_transport_is_bitwise_invisible() {
    let rounds = 24;
    for algo_name in ["lead", "choco", "dgd"] {
        for codec_name in ["topk", "qinf"] {
            for topo_name in ["ring", "er"] {
                let mem = run(algo_name, codec_name, topo_name, TransportMode::Mem, 1, rounds);
                assert!(mem.transport.is_none(), "mem mode must not report a summary");
                let edges = directed_edges(topo_name);
                for threads in [1usize, 3] {
                    for mode in
                        [TransportMode::Channel, TransportMode::Mux { per_worker: 8 }]
                    {
                        let tag = format!(
                            "{algo_name}/{codec_name}/{topo_name}/threads={threads}/{}",
                            mode.label()
                        );
                        let rec = run(algo_name, codec_name, topo_name, mode, threads, rounds);
                        assert_series_bitwise(&mem, &rec, &tag);
                        let s = rec.transport.as_ref().unwrap_or_else(|| panic!("{tag}: summary"));
                        assert_eq!(s.mode, mode.label(), "{tag}");
                        assert_eq!(s.frames_sent, edges * rounds as u64, "{tag}");
                        assert_eq!(s.frames_dropped, 0, "{tag}");
                        assert!(
                            s.bytes_on_wire >= s.frames_sent * frame::HEADER_LEN as u64,
                            "{tag}: envelopes must at least carry their headers"
                        );
                    }
                }
            }
        }
    }
}

/// Acceptance pin, rule 2: rerunning an identical transported spec — and
/// varying only the engine thread count — reproduces every series bit
/// for bit, frame counters included.
#[test]
fn transported_runs_deterministic_across_reruns_and_threads() {
    let reference = run("lead", "topk", "ring", TransportMode::Channel, 1, 30);
    let s0 = reference.transport.as_ref().expect("summary").clone();
    for (threads, label) in [(1usize, "rerun"), (3, "threads=3"), (8, "threads=8")] {
        let again = run("lead", "topk", "ring", TransportMode::Channel, threads, 30);
        assert_series_bitwise(&reference, &again, label);
        let s = again.transport.as_ref().unwrap();
        assert_eq!(s.frames_sent, s0.frames_sent, "{label}");
        assert_eq!(s.frames_dropped, s0.frames_dropped, "{label}");
        assert_eq!(s.bytes_on_wire, s0.bytes_on_wire, "{label}");
    }
    // The quantize family pins the same way (dense wire decode path).
    let qref = run("choco", "qinf", "er", TransportMode::Mux { per_worker: 8 }, 1, 30);
    let qagain = run("choco", "qinf", "er", TransportMode::Mux { per_worker: 8 }, 3, 30);
    assert_series_bitwise(&qref, &qagain, "qinf mux rerun");
}

/// Acceptance pin, rule 4: a multiplexed layout hosts far more agents
/// than pool workers — 64 agents over `mux:16` on 2 threads is 4 slots
/// total — and stays bitwise-equal to shared memory.
#[test]
fn multiplexed_slots_host_many_agents_per_worker() {
    let n = 64;
    let rounds = 10;
    let p: Arc<dyn Problem> = Arc::new(LinReg::synthetic(n, 20, 0.1, 7));
    let go = |transport: TransportMode| -> RunRecord {
        let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
        let cfg = EngineConfig { threads: 2, record_every: 2, transport, ..Default::default() };
        let mut e = Engine::new(cfg, mix, Arc::clone(&p));
        e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(5))), rounds)
    };
    let mem = go(TransportMode::Mem);
    let mux = go(TransportMode::Mux { per_worker: 16 });
    assert_series_bitwise(&mem, &mux, "mux:16 over 64 agents");
    let s = mux.transport.as_ref().unwrap();
    assert_eq!(s.mode, "mux:16");
    // Ring: 2 directed edges per agent.
    assert_eq!(s.frames_sent, (2 * n * rounds) as u64);
    assert_eq!(s.frames_dropped, 0);
}
