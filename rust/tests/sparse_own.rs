//! Differential harness for the sparse-own apply path.
//!
//! The engine's apply phase serves each agent's *own* decoded channel-0
//! message to the algorithm as an `OwnView` — the k published
//! `(index, value)` entries when the codec skipped the dense fill, a
//! dense slice otherwise. The contract (±0.0 rule on `OwnView`) says the
//! two arms are **bitwise** interchangeable; these tests pin it from two
//! directions:
//!
//! 1. end to end through the engine, for every compressed algorithm ×
//!    {top-k, rand-k, ∞-norm quantize} × {ring, star, Erdős–Rényi} ×
//!    thread counts: the sparse-own run must equal (a) the same run with
//!    an eagerly materialized dense own decode (`EagerDense` — the
//!    pre-sparse-own engine behavior), (b) the fully dense message path
//!    (`StripSparse` — no sparse view at all), and (c) the pre-pool
//!    `Scheduler::SpawnPerPhase` loop;
//! 2. at the unit level through `Algorithm::recv_all` directly, covering
//!    the uncompressed own-reading algorithms (NIDS, D², Exact Diffusion)
//!    whose sparse kernel arms the engine never drives, and planting
//!    ±0.0-valued selected entries to exercise the bit-exactness rule.

use std::sync::Arc;

use lead::algorithms::{
    choco::ChocoSgd, d2::D2, deepsqueeze::DeepSqueeze, exact_diffusion::ExactDiffusion,
    lead::Lead, nids::Nids, qdgd::Qdgd, Algorithm, Ctx, Exec, Inbox,
};
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::randk::RandK;
use lead::compress::topk::TopK;
use lead::compress::{CodecScratch, CompressedMsg, Compressor, EagerDense, StripSparse};
use lead::coordinator::engine::{Engine, EngineConfig, Scheduler};
use lead::problems::linreg::LinReg;
use lead::rng::Rng;
use lead::topology::{MixingRule, Topology};

#[derive(Clone, Copy)]
enum Variant {
    /// The codec as configured — sparsifiers take the sparse-own path.
    Sparse,
    /// `EagerDense`-wrapped: dense own decode materialized every round
    /// (pre-sparse-own behavior), sparse mixing kept.
    EagerOwn,
    /// `StripSparse`-wrapped: no sparse view at all — dense mixing and
    /// dense own consumption.
    StripAll,
}

fn codec(name: &str, v: Variant) -> Box<dyn Compressor> {
    match (name, v) {
        ("topk", Variant::Sparse) => Box::new(TopK::new(5)),
        ("topk", Variant::EagerOwn) => Box::new(EagerDense(TopK::new(5))),
        ("topk", Variant::StripAll) => Box::new(StripSparse(TopK::new(5))),
        ("randk", Variant::Sparse) => Box::new(RandK::new(5, true)),
        ("randk", Variant::EagerOwn) => Box::new(EagerDense(RandK::new(5, true))),
        ("randk", Variant::StripAll) => Box::new(StripSparse(RandK::new(5, true))),
        ("qinf", Variant::Sparse) => Box::new(QuantizeP::new(2, PNorm::Inf, 16)),
        ("qinf", Variant::EagerOwn) => Box::new(EagerDense(QuantizeP::new(2, PNorm::Inf, 16))),
        ("qinf", Variant::StripAll) => Box::new(StripSparse(QuantizeP::new(2, PNorm::Inf, 16))),
        _ => unreachable!("unknown codec {name}"),
    }
}

fn algo(name: &str) -> Box<dyn Algorithm> {
    match name {
        "lead" => Box::new(Lead::paper_default()),
        "choco" => Box::new(ChocoSgd::new(0.5)),
        "qdgd" => Box::new(Qdgd::new(0.2)),
        "deepsqueeze" => Box::new(DeepSqueeze::new(0.2)),
        _ => unreachable!("unknown algorithm {name}"),
    }
}

/// Engine-level differential: sparse-own apply is bitwise-identical to
/// the dense decode path and to the pre-PR spawn-per-phase loop, across
/// every compressed algorithm × codec × topology × thread count.
#[test]
fn sparse_own_apply_bitwise_equals_dense_and_legacy() {
    let topologies = [
        ("ring", Topology::Ring),
        ("star", Topology::Star),
        ("er", Topology::ErdosRenyi { p: 0.6, seed: 5 }),
    ];
    for (topo_name, topo) in &topologies {
        for algo_name in ["lead", "choco", "qdgd", "deepsqueeze"] {
            for codec_name in ["topk", "randk", "qinf"] {
                for threads in [1usize, 3] {
                    let run = |scheduler: Scheduler, v: Variant| {
                        let n = 6;
                        let p = LinReg::synthetic(n, 24, 0.1, 17);
                        let mix = topo.build(n, MixingRule::MetropolisHastings);
                        let mut e = Engine::new(
                            EngineConfig {
                                eta: 0.02,
                                threads,
                                record_every: 7,
                                scheduler,
                                ..Default::default()
                            },
                            mix,
                            Arc::new(p),
                        );
                        e.run(algo(algo_name), Some(codec(codec_name, v)), 30)
                    };
                    let sparse = run(Scheduler::Persistent, Variant::Sparse);
                    let references = [
                        ("eager-own-dense", run(Scheduler::Persistent, Variant::EagerOwn)),
                        ("strip-sparse", run(Scheduler::Persistent, Variant::StripAll)),
                        ("legacy-scheduler", run(Scheduler::SpawnPerPhase, Variant::Sparse)),
                    ];
                    for (ref_name, reference) in &references {
                        assert_eq!(sparse.series.len(), reference.series.len());
                        for (a, b) in sparse.series.iter().zip(&reference.series) {
                            let at = format!(
                                "{topo_name}/{algo_name}/{codec_name} threads={threads} \
                                 vs {ref_name}, round {}",
                                a.round
                            );
                            assert_eq!(a.dist_opt.to_bits(), b.dist_opt.to_bits(), "dist {at}");
                            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits(), "cons {at}");
                            assert_eq!(a.comp_err.to_bits(), b.comp_err.to_bits(), "cerr {at}");
                            assert_eq!(a.bits_per_agent, b.bits_per_agent, "bits {at}");
                        }
                    }
                }
            }
        }
    }
}

/// Unit-level differential through `Algorithm::recv_all` for every
/// own-reading algorithm — including the uncompressed ones (NIDS, D²,
/// Exact Diffusion) the engine never drives with sparse messages. Two
/// copies of each algorithm receive the *same* round: one through stale
/// sparse messages (`OwnView::Sparse` arm), one through the eagerly
/// rebuilt dense vectors (`OwnView::Dense` arm). States must stay
/// bitwise-identical. Payload coordinates 0/1 are forced to ±0.0 so the
/// `k ≥ d` codec publishes explicitly zero-valued selected entries (the
/// ±0.0 bit-exactness rule).
#[test]
fn own_view_sparse_arm_matches_dense_for_all_own_reading_algorithms() {
    let n = 5usize;
    let d = 37usize;
    let builders: Vec<(&str, fn() -> Box<dyn Algorithm>)> = vec![
        ("lead", || Box::new(Lead::paper_default())),
        ("choco", || Box::new(ChocoSgd::new(0.5))),
        ("qdgd", || Box::new(Qdgd::new(0.2))),
        ("deepsqueeze", || Box::new(DeepSqueeze::new(0.2))),
        ("nids", || Box::new(Nids::new())),
        ("d2", || Box::new(D2::new())),
        ("exact_diffusion", || Box::new(ExactDiffusion::new())),
    ];
    // k < d exercises the genuinely sparse regime; k ≥ d selects every
    // coordinate, including the planted ±0.0 entries.
    let codecs: Vec<Box<dyn Compressor>> =
        vec![Box::new(TopK::new(7)), Box::new(TopK::new(d)), Box::new(RandK::new(7, true))];
    let mix = Topology::Ring.build(n, MixingRule::MetropolisHastings);

    for (name, build) in &builders {
        for comp in &codecs {
            let mut rng = Rng::new(0xA11CE ^ comp.name().len() as u64);
            let mut a = build(); // sparse arm
            let mut b = build(); // dense arm
            let eta = 0.05;
            let mut x0 = vec![vec![0.0f64; d]; n];
            let mut g0 = vec![vec![0.0f64; d]; n];
            for i in 0..n {
                rng.fill_normal(&mut x0[i], 1.0);
                rng.fill_normal(&mut g0[i], 1.0);
            }
            let ctx0 = Ctx { mix: &mix, round: 0, eta };
            a.init(&ctx0, &x0, &g0);
            b.init(&ctx0, &x0, &g0);
            assert_eq!(a.spec().channels, 1, "{name}: harness assumes one channel");

            let mut pay_a = vec![vec![vec![0.0f64; d]; 1]; n];
            let mut pay_b = vec![vec![vec![0.0f64; d]; 1]; n];
            let mut mixed = vec![vec![vec![0.0f64; d]; 1]; n];
            let mut g = vec![vec![0.0f64; d]; n];
            let mut scratch = CodecScratch::default();

            for round in 1..=4usize {
                let ctx = Ctx { mix: &mix, round, eta };
                for gi in g.iter_mut() {
                    rng.fill_normal(gi, 1.0);
                }
                for i in 0..n {
                    a.send(&ctx, i, &g[i], &mut pay_a[i]);
                    b.send(&ctx, i, &g[i], &mut pay_b[i]);
                    // Identical state ⇒ identical payloads; drift here
                    // means a previous round's apply already diverged.
                    for (u, v) in pay_a[i][0].iter().zip(&pay_b[i][0]) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{name}/{}: send drift", comp.name());
                    }
                    // Plant exact and negative zeros (both copies see the
                    // same wire, so the differential stays valid).
                    pay_a[i][0][0] = 0.0;
                    pay_a[i][0][1] = -0.0;
                    pay_b[i][0][0] = 0.0;
                    pay_b[i][0][1] = -0.0;
                }
                // One compression per agent; the sparse copy keeps the
                // stale lazy message, the dense copy gets ensure_dense.
                let msgs_sparse: Vec<CompressedMsg> = (0..n)
                    .map(|i| {
                        let mut m = CompressedMsg::with_dim(d);
                        let mut r = rng.derive((round * n + i) as u64);
                        comp.compress_into(&pay_a[i][0], &mut r, &mut m, &mut scratch);
                        m
                    })
                    .collect();
                let msgs_dense: Vec<CompressedMsg> = msgs_sparse
                    .iter()
                    .map(|m| {
                        let mut m = m.clone();
                        m.ensure_dense();
                        m
                    })
                    .collect();
                // One shared mix (from the dense decode) for both arms —
                // this test isolates the *own* path; mixing equivalence
                // has its own proptest.
                for i in 0..n {
                    mixed[i][0].fill(0.0);
                    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                        lead::linalg::axpy(mix.weight(i, j), &msgs_dense[j].values, &mut mixed[i][0]);
                    }
                }
                let inbox_a = Inbox::with_decoded0(&pay_a, &mixed, &msgs_sparse);
                a.recv_all(&ctx, &g, &inbox_a, Exec::seq());
                let inbox_b = Inbox::with_decoded0(&pay_b, &mixed, &msgs_dense);
                b.recv_all(&ctx, &g, &inbox_b, Exec::seq());
                for i in 0..n {
                    for (t, (u, v)) in a.x(i).iter().zip(b.x(i)).enumerate() {
                        assert_eq!(
                            u.to_bits(),
                            v.to_bits(),
                            "{name}/{}: round {round} agent {i} coord {t}: sparse {u} vs dense {v}",
                            comp.name()
                        );
                    }
                }
            }
        }
    }
}
