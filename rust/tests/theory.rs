//! Theory-validation integration tests: every testable claim in the
//! paper's analysis sections, exercised through the full engine.

use lead::algorithms::lead::{Lead, LeadParams};
use lead::algorithms::{dgd::Dgd, nids::Nids, Algorithm, Ctx};
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::{identity::Identity, randk::RandK, Compressor};
use lead::coordinator::engine::{Engine, EngineConfig, Schedule};
use lead::prop::forall;
use lead::prop_assert;
use lead::problems::{linreg::LinReg, Problem};
use lead::rng::Rng;
use lead::topology::{spectral, MixingRule, Topology};

fn engine(n: usize, d: usize, seed: u64, topo: Topology) -> Engine {
    let p = LinReg::synthetic(n, d, 0.1, seed);
    let mix = topo.build(n, MixingRule::UniformNeighbors);
    Engine::new(EngineConfig { record_every: 10, ..Default::default() }, mix, std::sync::Arc::new(p))
}

/// Theorem 1 headline: linear convergence under compression, for several
/// compression levels and topologies.
#[test]
fn linear_convergence_across_compressors_and_topologies() {
    for topo in [Topology::Ring, Topology::FullyConnected, Topology::Star] {
        for bits in [2u32, 4] {
            let mut e = engine(8, 24, 7, topo.clone());
            let rec = e.run(
                Box::new(Lead::paper_default()),
                Some(Box::new(QuantizeP::new(bits, PNorm::Inf, 512))),
                800,
            );
            assert!(
                rec.last().dist_opt < 1e-8,
                "{topo:?}/{bits}bit: {}",
                rec.last().dist_opt
            );
        }
    }
}

/// Remark 5: arbitrary compression precision — even 1-bit levels (the
/// most aggressive unbiased setting) must converge with suitable (γ, α).
#[test]
fn one_bit_quantization_converges_with_tuned_gamma() {
    let mut e = engine(8, 24, 11, Topology::Ring);
    let rec = e.run(
        Box::new(Lead::new(LeadParams { gamma: 0.6, alpha: 0.5 })),
        Some(Box::new(QuantizeP::new(1, PNorm::Inf, 64))),
        1500,
    );
    assert!(rec.last().dist_opt < 1e-6, "1-bit: {}", rec.last().dist_opt);
}

/// LEAD also works with unbiased rand-k sparsification (Assumption 2 is
/// the only requirement on Q).
#[test]
fn randk_unbiased_converges() {
    let mut e = engine(6, 24, 13, Topology::Ring);
    // C = d/k − 1 = 2 ⇒ tighter γ per Eq. (9).
    let rec = e.run(
        Box::new(Lead::new(LeadParams { gamma: 0.3, alpha: 0.3 })),
        Some(Box::new(RandK::new(8, true))),
        12000,
    );
    assert!(rec.last().dist_opt < 1e-6, "rand-k: {}", rec.last().dist_opt);
}

/// The empirical contraction factor must not beat the best branch of the
/// Theorem 1 bound's *uncompressed* limit (sanity: we cannot converge
/// faster than gradient descent on the same conditioning), and must be
/// strictly < 1.
#[test]
fn empirical_rate_is_linear_and_sane() {
    let mut e = engine(8, 24, 17, Topology::Ring);
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::new(2, PNorm::Inf, 512))),
        700,
    );
    let rho = rec.empirical_rho(1e-10).expect("need decay segment");
    assert!(rho < 1.0, "ρ̂ = {rho}");
    assert!(rho > 0.5, "suspiciously fast ρ̂ = {rho} — metric bug?");
}

/// Corollary 2: consensus error decays at the same linear rate (full
/// gradient ⇒ σ = 0 ⇒ exact consensus in the limit).
#[test]
fn consensus_error_vanishes_linearly() {
    let mut e = engine(8, 24, 19, Topology::Ring);
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::new(2, PNorm::Inf, 512))),
        600,
    );
    assert!(rec.last().consensus < 1e-8, "consensus {}", rec.last().consensus);
    // Monotone-ish decay: late-phase consensus ≪ early-phase.
    let early = rec.series[2].consensus;
    assert!(rec.last().consensus < 1e-4 * early.max(1e-12));
}

/// §3.1/Eq. 3: the *global average* evolves exactly as inexact SGD,
/// x̄^{k+1} = x̄^k − η ḡ^k, regardless of compression error. We verify the
/// equivalent invariant Σ_i d_i^k = 0 plus the average-iterate identity by
/// driving LEAD manually with aggressive 1-bit compression.
#[test]
fn global_average_view_invariant_under_compression() {
    forall(20, 0xAB5E11, |gen| {
        let n = 3 + gen.usize_in(0..=3) * 2; // 3,5,7,9
        let d = 8 + gen.usize_in(0..=16);
        let p = LinReg::synthetic(n, d, 0.1, gen.case_seed);
        let topo = gen.choose(&[Topology::Ring, Topology::Star, Topology::FullyConnected]).clone();
        let mix = topo.build(n, MixingRule::MetropolisHastings);
        let comp = QuantizeP::new(1, PNorm::Inf, 16);
        let eta = 0.05f64;
        let mut algo = Lead::new(LeadParams { gamma: 0.4, alpha: 0.4 });

        // Manual round loop so we can check invariants mid-flight.
        let x0 = vec![vec![0.0f64; d]; n];
        let mut g = vec![vec![0.0f64; d]; n];
        for i in 0..n {
            p.grad_full(i, &x0[i], &mut g[i]);
        }
        algo.init(&Ctx { mix: &mix, round: 0, eta }, &x0, &g);
        let mut rng = Rng::new(gen.case_seed ^ 0x5ca1ab1e);
        let mut payload = vec![vec![vec![0.0f64; d]; 1]; n];
        let mut msgs: Vec<_> = (0..n).map(|_| lead::compress::CompressedMsg::with_dim(d)).collect();

        for round in 1..=25usize {
            let ctx = Ctx { mix: &mix, round, eta };
            for i in 0..n {
                p.grad_full(i, algo.x(i), &mut g[i]);
            }
            // Average BEFORE the round.
            let mut xbar_before = vec![0.0f64; d];
            let mut gbar = vec![0.0f64; d];
            for i in 0..n {
                lead::linalg::axpy(1.0 / n as f64, algo.x(i), &mut xbar_before);
                lead::linalg::axpy(1.0 / n as f64, &g[i], &mut gbar);
            }
            for i in 0..n {
                let gi = g[i].clone();
                algo.send(&ctx, i, &gi, &mut payload[i]);
            }
            for i in 0..n {
                comp.compress(&payload[i][0], &mut rng, &mut msgs[i]);
            }
            for i in 0..n {
                let mut mixed = vec![vec![0.0f64; d]];
                for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                    lead::linalg::axpy(mix.weight(i, j), &msgs[j].values, &mut mixed[0]);
                }
                let self_dec: Vec<&[f64]> = vec![msgs[i].values.as_slice()];
                let mixed_refs: Vec<&[f64]> = mixed.iter().map(|v| v.as_slice()).collect();
                let gi = g[i].clone();
                algo.recv(&ctx, i, &gi, &self_dec, &mixed_refs);
            }
            // Invariant 1: Σ_i d_i = 0 despite 1-bit compression error.
            for t in 0..d {
                let s: f64 = (0..n).map(|i| algo.dual(i)[t]).sum();
                prop_assert!(s.abs() < 1e-8 * n as f64, "round {round}: Σd[{t}] = {s}");
            }
            // Invariant 2: x̄⁺ = x̄ − η ḡ exactly (Eq. 3).
            let mut xbar_after = vec![0.0f64; d];
            for i in 0..n {
                lead::linalg::axpy(1.0 / n as f64, algo.x(i), &mut xbar_after);
            }
            for t in 0..d {
                let want = xbar_before[t] - eta * gbar[t];
                prop_assert!(
                    (xbar_after[t] - want).abs() < 1e-9 * (1.0 + want.abs()),
                    "round {round}, coord {t}: x̄⁺ = {} want {want}",
                    xbar_after[t]
                );
            }
        }
        Ok(())
    });
}

/// The paper's compression-error dynamics (Fig. 1d / the consensus-error
/// bound of Cor. 2, which needs no bounded-gradient assumption): LEAD's
/// recorded `comp_err = ‖Y − H‖`-style residual must decay
/// *geometrically alongside the primal error* — here under biased top-k
/// sparsification on a heterogeneous logistic regression. This pins the
/// convergence behavior the sparse-own apply path must preserve: a bug
/// that silently perturbed the own-decode values would break the
/// geometric comp_err decay long before it broke a loose final-accuracy
/// check.
#[test]
fn lead_topk_comp_err_decays_geometrically_with_primal_error() {
    use lead::compress::topk::TopK;
    use lead::problems::{logreg::LogReg, DataSplit};
    let p = LogReg::synthetic(4, 160, 10, 4, 1e-2, DataSplit::Heterogeneous, 5, true);
    let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig { eta: 0.1, record_every: 100, ..Default::default() },
        mix,
        std::sync::Arc::new(p),
    );
    // k = d/2 (d = d_feat · classes = 40) — the sparse-own steady state.
    let rec = e.run(
        Box::new(Lead::new(LeadParams { gamma: 0.5, alpha: 0.5 })),
        Some(Box::new(TopK::new(20))),
        8000,
    );
    // Primal error makes solid progress…
    let first = rec.series.first().unwrap().dist_opt;
    let last = rec.last();
    assert!(
        last.dist_opt < 1e-2 * first,
        "primal error stalled under top-k: {first} -> {}",
        last.dist_opt
    );
    // …and the compression error vanishes with it rather than plateauing
    // (the QDGD/DeepSqueeze failure mode, Fig. 1d).
    let early_comp = rec
        .series
        .iter()
        .find(|m| m.round > 0)
        .expect("need an observed round")
        .comp_err;
    assert!(early_comp > 0.0, "top-k at k < d must have nonzero early compression error");
    assert!(
        last.comp_err < 1e-2 * early_comp,
        "comp_err plateaued: early {early_comp} vs final {}",
        last.comp_err
    );
    // Geometric decay: a decisive log-linear fit, for both metrics.
    let rho_comp = rec
        .empirical_rho_of(|m| m.comp_err, last.comp_err.max(1e-14))
        .expect("need a comp_err decay segment");
    assert!(
        rho_comp < 0.9995,
        "comp_err decay not geometric: fitted per-round factor {rho_comp}"
    );
    let rho_primal = rec.empirical_rho(last.dist_opt.max(1e-14)).expect("need a decay segment");
    assert!(rho_primal < 0.9995, "primal decay not geometric: ρ̂ = {rho_primal}");
}

/// DGD with the same stepsize stalls at an O(η) bias while LEAD converges —
/// the paper's central heterogeneous-data comparison.
#[test]
fn lead_beats_dgd_under_heterogeneity() {
    let mut e1 = engine(8, 24, 23, Topology::Ring);
    let lead_rec = e1.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::new(2, PNorm::Inf, 512))),
        500,
    );
    let mut e2 = engine(8, 24, 23, Topology::Ring);
    let dgd_rec = e2.run(Box::new(Dgd::new()), None, 500);
    assert!(lead_rec.last().dist_opt < 1e-6);
    assert!(dgd_rec.last().dist_opt > 1e-3, "DGD bias unexpectedly small");
    // LEAD spends ~10× fewer bits AND reaches far better accuracy.
    assert!(lead_rec.last().bits_per_agent < 0.2 * dgd_rec.last().bits_per_agent);
}

/// Theorem 1 parameter ranges: running inside the admissible (γ, α) region
/// given the measured compression constant must converge; the theoretical
/// ρ must also upper-bound a fitted empirical rate reasonably (theory is
/// conservative, so we only check direction: ρ̂ finite < 1).
#[test]
fn theorem1_parameter_recipe_converges() {
    let n = 8;
    let p = LinReg::synthetic(n, 16, 0.1, 29);
    let (mu, l) = p.mu_l().unwrap();
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let comp = QuantizeP::new(2, PNorm::Inf, 512);
    let c = comp.variance_constant(16).unwrap();
    let eta = 2.0 / (mu + l);
    let gamma = 0.9 * spectral::gamma_upper_bound(&mix, c, mu, eta);
    let (alo, ahi) = spectral::alpha_interval(&mix, c, mu, eta, gamma);
    assert!(alo <= ahi, "empty α interval: ({alo}, {ahi})");
    let alpha = 0.5 * (alo + ahi);
    let rho_theory = spectral::rho_theorem1(&mix, c, mu, eta, gamma, alpha);
    assert!(rho_theory < 1.0);

    let mut e = Engine::new(
        EngineConfig { eta, record_every: 10, ..Default::default() },
        mix,
        std::sync::Arc::new(p),
    );
    let rec = e.run(
        Box::new(Lead::new(LeadParams { gamma: gamma as f64, alpha: alpha as f64 })),
        Some(Box::new(comp)),
        3000,
    );
    assert!(
        rec.last().dist_opt < 1e-8,
        "theory-recipe run did not converge: {}",
        rec.last().dist_opt
    );
    let rho_hat = rec.empirical_rho(1e-10).unwrap();
    assert!(
        rho_hat <= rho_theory + 0.02,
        "measured ρ̂ {rho_hat} worse than theoretical bound {rho_theory}"
    );
}

/// Theorem 2: diminishing stepsize + stochastic-free full gradient still
/// converges (slower), and with Identity compression LEAD keeps its linear
/// behavior under a constant schedule — regression guard on schedules.
#[test]
fn schedules() {
    let p = LinReg::synthetic(4, 16, 0.1, 31);
    let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig {
            eta: 0.2,
            schedule: Schedule::Diminishing { t0: 500.0 },
            record_every: 50,
            ..Default::default()
        },
        mix,
        std::sync::Arc::new(p),
    );
    let rec = e.run(Box::new(Lead::paper_default()), Some(Box::new(Identity)), 4000);
    assert!(rec.last().dist_opt < 1e-5, "diminishing: {}", rec.last().dist_opt);
}

/// NIDS == LEAD(identity, γ=1) on a *heterogeneous logistic regression*
/// problem too (the equivalence is algebraic, not linreg-specific).
#[test]
fn lead_nids_equivalence_on_logreg() {
    use lead::problems::{logreg::LogReg, DataSplit};
    let build = || {
        let p = LogReg::synthetic(4, 160, 10, 4, 1e-3, DataSplit::Heterogeneous, 41, true);
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        Engine::new(EngineConfig { record_every: 20, ..Default::default() }, mix, std::sync::Arc::new(p))
    };
    let rec_lead = build().run(
        Box::new(Lead::new(LeadParams { gamma: 1.0, alpha: 0.5 })),
        Some(Box::new(Identity)),
        300,
    );
    let rec_nids = build().run(Box::new(Nids::new()), None, 300);
    for (a, b) in rec_lead.series.iter().zip(&rec_nids.series) {
        assert!(
            (a.dist_opt - b.dist_opt).abs() <= 1e-8 * (1.0 + a.dist_opt.abs()),
            "round {}: {} vs {}",
            a.round,
            a.dist_opt,
            b.dist_opt
        );
    }
}
