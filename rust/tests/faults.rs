//! Differential + determinism harness for the fault-injection layer
//! (`lead::faults`) and the graceful-degradation engine path.
//!
//! Pins the contract from `coordinator::engine` §Fault injection:
//!
//! 1. **Off ⇒ identity**: `faults: None` and a no-op plan both take the
//!    historical round loop bit for bit — trajectories, sim_time, and
//!    the absence of any fault summary.
//! 2. **On ⇒ determinism**: a live plan perturbs trajectories by design,
//!    but bitwise-identically across engine thread counts and reruns
//!    (fault draws come from the dedicated `streams::FAULT` root with
//!    fixed per-round draw counts).
//! 3. **Graceful degradation**: LEAD keeps converging under ≥5% link
//!    loss plus a crash/recover cycle on the heterogeneous logistic
//!    workload, while the inexact DGD baseline ends up further from x*.
//! 4. **Budget + cap surfacing**: `time_budget` stops runs early (the
//!    crossing round still observed), and simnet retransmit-cap
//!    force-deliveries are demoted to real losses under a plan.
//! 5. **Transport routing**: the same plan over the `channel` / `mux`
//!    transports takes the literal drop path — lost links are frames
//!    that never leave the sender — and stays bitwise-identical to the
//!    shared-memory degraded mix (`transport` §Transport rule 4).

use lead::algorithms::{dgd::Dgd, lead::Lead};
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::Compressor;
use lead::coordinator::engine::{Engine, EngineConfig, Schedule};
use lead::coordinator::metrics::RunRecord;
use lead::faults::FaultPlan;
use lead::problems::linreg::LinReg;
use lead::problems::logreg::LogReg;
use lead::problems::DataSplit;
use lead::simnet::NetModel;
use lead::topology::{MixingRule, Topology};
use lead::transport::TransportMode;
use std::sync::Arc;

fn codec() -> Option<Box<dyn Compressor>> {
    Some(Box::new(QuantizeP::new(2, PNorm::Inf, 64)))
}

/// One short LEAD run on the Fig. 1-shaped workload with an optional
/// fault plan / net model / time budget.
fn lead_run(
    faults: Option<FaultPlan>,
    net: Option<&str>,
    time_budget: Option<f64>,
    threads: usize,
    rounds: usize,
) -> RunRecord {
    let n = 8;
    let p = LinReg::synthetic(n, 40, 0.1, 3);
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let cfg = EngineConfig {
        threads,
        record_every: 7,
        net: net.map(|s| NetModel::parse(s).expect("bad test model")),
        faults,
        time_budget,
        ..Default::default()
    };
    let mut e = Engine::new(cfg, mix, Arc::new(p));
    e.run(Box::new(Lead::paper_default()), codec(), rounds)
}

/// Same workload as [`lead_run`], but over an explicit transport mode.
fn lead_run_over(
    transport: TransportMode,
    faults: Option<FaultPlan>,
    threads: usize,
    rounds: usize,
) -> RunRecord {
    let n = 8;
    let p = LinReg::synthetic(n, 40, 0.1, 3);
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let cfg = EngineConfig { threads, record_every: 7, faults, transport, ..Default::default() };
    let mut e = Engine::new(cfg, mix, Arc::new(p));
    e.run(Box::new(Lead::paper_default()), codec(), rounds)
}

fn assert_bitwise_equal(a: &RunRecord, b: &RunRecord, tag: &str) {
    assert_eq!(a.series.len(), b.series.len(), "{tag}: series length");
    for (ma, mb) in a.series.iter().zip(&b.series) {
        assert_eq!(ma.round, mb.round, "{tag}");
        assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.consensus.to_bits(), mb.consensus.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.comp_err.to_bits(), mb.comp_err.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.sim_time.to_bits(), mb.sim_time.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.bits_per_agent, mb.bits_per_agent, "{tag} round {}", ma.round);
        assert_eq!(
            (ma.crashed, ma.lost, ma.stale, ma.renormed),
            (mb.crashed, mb.lost, mb.stale, mb.renormed),
            "{tag} round {}",
            ma.round
        );
    }
}

/// Acceptance pin, direction one: with the plan absent — or present but
/// no-op — the engine is bitwise-identical to the pre-fault round loop,
/// with and without the simnet timing overlay.
#[test]
fn absent_and_noop_plans_are_bitwise_identical() {
    for net in [None, Some("lognormal:1e-3:1e8:0.75")] {
        let off = lead_run(None, net, None, 1, 50);
        let noop = lead_run(Some(FaultPlan::default()), net, None, 1, 50);
        assert_bitwise_equal(&off, &noop, "noop plan");
        assert!(off.faults.is_none() && noop.faults.is_none(), "no summary when inert");
        assert!(!off.stopped_early && !noop.stopped_early);
        for m in &off.series {
            assert_eq!((m.crashed, m.lost, m.stale, m.renormed), (0, 0, 0, 0));
        }
    }
}

/// Acceptance pin, direction two: a live plan perturbs the trajectory
/// (that is its job) but stays bitwise-deterministic across engine
/// thread counts and reruns — counters included.
#[test]
fn faulty_runs_deterministic_across_threads_and_reruns() {
    let plan = FaultPlan::parse("loss:0.05+churn:0.02:down=3:stale=2").unwrap();
    let clean = lead_run(None, None, None, 1, 50);
    let reference = lead_run(Some(plan), None, None, 1, 50);
    assert!(
        reference
            .series
            .iter()
            .zip(&clean.series)
            .any(|(a, b)| a.dist_opt.to_bits() != b.dist_opt.to_bits()),
        "a live fault plan must actually perturb the trajectory"
    );
    let summary = reference.faults.as_ref().expect("live plan ⇒ summary");
    assert!(summary.lost > 0, "5% loss over 50 rounds never fired");
    assert_eq!(summary.plan, plan.label());
    for threads in [1usize, 3, 8] {
        let rerun = lead_run(Some(plan), None, None, threads, 50);
        assert_bitwise_equal(&reference, &rerun, &format!("threads={threads}"));
        let s = rerun.faults.as_ref().unwrap();
        assert_eq!(summary.lost, s.lost, "threads={threads}");
        assert_eq!(summary.stale, s.stale, "threads={threads}");
        assert_eq!(summary.crashed_agent_rounds, s.crashed_agent_rounds, "threads={threads}");
        assert_eq!(summary.renormalized_rows, s.renormalized_rows, "threads={threads}");
        assert_eq!(summary.down_rounds, s.down_rounds, "threads={threads}");
    }
}

/// The one-shot crash event has exact, countable bookkeeping: ⌈frac·n⌉
/// agents down for exactly `down=` rounds, renormalized rows while they
/// are gone, and full recovery afterwards.
#[test]
fn crash_event_counts_and_recovers() {
    // ⌈0.25·8⌉ = 2 agents crash at round 10 for 5 rounds.
    let plan = FaultPlan::parse("crash:0.25:10:down=5").unwrap();
    let rec = lead_run(Some(plan), None, None, 1, 50);
    let s = rec.faults.as_ref().unwrap();
    assert_eq!(s.crashed_agent_rounds, 2 * 5, "2 agents × 5 rounds");
    assert_eq!(s.down_rounds.len(), 8);
    assert_eq!(s.down_rounds.iter().filter(|&&r| r == 5).count(), 2);
    assert_eq!(s.down_rounds.iter().filter(|&&r| r == 0).count(), 6);
    // A crashed agent's out-links are lost on the ring: every live
    // neighbor renormalizes while the outage lasts.
    assert!(s.lost > 0 && s.renormalized_rows > 0);
    // The trajectory still reaches a sane final state (no NaN poisoning
    // from the frozen agents' reference points).
    assert!(rec.last().dist_opt.is_finite());
    assert!(rec.last().consensus.is_finite());
}

/// Graceful degradation (the tentpole's convergence claim): on the
/// heterogeneous logistic workload under 5% link loss plus a mid-run
/// crash/recover cycle, LEAD still makes an order-of-magnitude style
/// progress, while inexact DGD under the *identical* fault schedule ends
/// up strictly further from x*.
#[test]
fn lead_converges_under_faults_while_dgd_degrades() {
    let plan = FaultPlan::parse("loss:0.05+crash:0.25:500:down=100").unwrap();
    let run = |algo: Box<dyn lead::algorithms::Algorithm>,
               comp: Option<Box<dyn Compressor>>|
     -> RunRecord {
        let p = LogReg::synthetic(4, 160, 10, 4, 1e-2, DataSplit::Heterogeneous, 5, true);
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let cfg = EngineConfig {
            eta: 0.5,
            schedule: Schedule::Diminishing { t0: 200.0 },
            batch_size: Some(8),
            record_every: 50,
            faults: Some(plan),
            ..Default::default()
        };
        let mut e = Engine::new(cfg, mix, Arc::new(p));
        e.run(algo, comp, 2000)
    };
    let lead_rec = run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::new(4, PNorm::Inf, 512))),
    );
    let first = lead_rec.series.first().unwrap().dist_opt;
    let last = lead_rec.last().dist_opt;
    assert!(
        last.is_finite() && last < 0.5 * first,
        "LEAD under faults made no progress: {first} -> {last}"
    );
    // The crash cycle actually happened (one agent, 100 rounds).
    let s = lead_rec.faults.as_ref().unwrap();
    assert_eq!(s.crashed_agent_rounds, 100);
    assert!(s.lost > 0);
    // DGD sees the same plan (same fault stream, same schedule) and ends
    // further out — or diverges outright.
    let dgd_rec = run(Box::new(Dgd::new()), None);
    let dgd_last = dgd_rec.last().dist_opt;
    assert!(
        dgd_last.is_nan() || dgd_last > last,
        "DGD under faults should degrade past LEAD: dgd {dgd_last} vs lead {last}"
    );
}

/// Satellite: `time_budget` stops a run once sim_time crosses it — the
/// crossing round completes and is observed, the record flags
/// stopped_early, and a generous budget changes nothing.
#[test]
fn time_budget_stops_early_and_observes_the_crossing_round() {
    let full = lead_run(None, None, None, 1, 50);
    let total = full.last().sim_time;
    assert!(total > 0.0);

    let capped = lead_run(None, None, Some(total / 2.0), 1, 50);
    assert!(capped.stopped_early, "half the budget must stop early");
    assert!(capped.series.len() < full.series.len());
    let last = capped.last();
    assert!(last.sim_time >= total / 2.0, "budget crossed before stopping");
    assert!(last.round < 50);
    // The crossing round is observed even off the record_every cadence
    // (record_every = 7 here), so the final sample is the stop point.
    assert_eq!(
        capped.series.iter().filter(|m| m.sim_time >= total / 2.0).count(),
        1,
        "exactly the crossing round is recorded past the budget"
    );

    let roomy = lead_run(None, None, Some(total * 2.0), 1, 50);
    assert!(!roomy.stopped_early);
    assert_bitwise_equal(&full, &roomy, "unreached budget");

    // Budgets compose with faults: still early-stopped, still summarized.
    let plan = FaultPlan::parse("loss:0.05").unwrap();
    let faulted = lead_run(Some(plan), None, Some(total / 2.0), 1, 50);
    assert!(faulted.stopped_early);
    assert!(faulted.faults.is_some());
}

/// Satellite: a `loss:P` plan routed through the transport drop path —
/// frames withheld at the sender instead of links zeroed in the mix —
/// is bitwise-identical to the same plan over shared memory, and the
/// frame counters reconcile exactly with the fault bookkeeping.
#[test]
fn loss_plan_over_channel_matches_shared_memory_bitwise() {
    let rounds = 50;
    // Ring over 8 agents: 16 directed edges per round.
    let edges_per_round = 16u64;

    let plan = FaultPlan::parse("loss:0.1").unwrap();
    let mem = lead_run_over(TransportMode::Mem, Some(plan), 1, rounds);
    assert!(mem.transport.is_none());
    let mem_lost = mem.faults.as_ref().expect("live plan ⇒ summary").lost;
    assert!(mem_lost > 0, "10% loss over 50 rounds never fired");
    for mode in [TransportMode::Channel, TransportMode::Mux { per_worker: 4 }] {
        for threads in [1usize, 3] {
            let tag = format!("{}/threads={threads}", mode.label());
            let rec = lead_run_over(mode, Some(plan), threads, rounds);
            assert_bitwise_equal(&mem, &rec, &tag);
            assert_eq!(mem_lost, rec.faults.as_ref().unwrap().lost, "{tag}");
            let s = rec.transport.as_ref().expect("transported run ⇒ summary");
            // Pure loss plan, no crashes or staleness: every directed
            // edge each round either carries a frame or is the drop path.
            assert_eq!(s.frames_dropped, mem_lost, "{tag}: lost links are unsent frames");
            assert_eq!(
                s.frames_sent + s.frames_dropped,
                edges_per_round * rounds as u64,
                "{tag}"
            );
        }
    }

    // Staleness and crashes compose: stale links also withhold frames
    // (the receiver replays its cached payload), crashed receivers take
    // frames down with them — still bitwise-equal to the degraded mix.
    let churn = FaultPlan::parse("loss:0.05+churn:0.02:down=3:stale=2").unwrap();
    let cmem = lead_run_over(TransportMode::Mem, Some(churn), 1, rounds);
    let cchan = lead_run_over(TransportMode::Channel, Some(churn), 3, rounds);
    assert_bitwise_equal(&cmem, &cchan, "churn over channel");
    let cs = cchan.transport.as_ref().unwrap();
    assert!(cs.frames_dropped > 0);
    // Every directed edge is exactly one of {sent, dropped} each round,
    // whatever the mixture of loss, staleness, and crashes.
    assert_eq!(cs.frames_sent + cs.frames_dropped, edges_per_round * rounds as u64);
}

/// Satellite: transfers force-delivered at the simnet retransmit cap are
/// demoted to real losses under a fault plan — surfaced both in the net
/// summary (`capped`) and the fault summary (`capped_losses`).
#[test]
fn capped_transfers_become_losses_under_a_plan() {
    let net = Some("uniform:1e-4:1e9:drop=0.99:seed=5");
    let plan = FaultPlan::parse("loss:0.01").unwrap();
    let rec = lead_run(Some(plan), net, None, 1, 20);
    let n = rec.net.as_ref().expect("net summary");
    let f = rec.faults.as_ref().expect("fault summary");
    assert!(n.capped > 0, "drop=0.99 over 20 rounds never hit the retransmit cap");
    assert!(f.capped_losses > 0, "capped transfers were not demoted to losses");
    // Plan-lost transfers never reach the timer's queue, so only
    // Delivered links can be capped: the demotions are a subset.
    assert!(f.capped_losses <= n.capped, "{} demotions > {} caps", f.capped_losses, n.capped);
    assert!(f.lost >= f.capped_losses, "demotions count as losses");
    // Without a plan the same lossy model is a timing-only fiction of
    // delivery: trajectory identical to the clean-network run.
    let fiction = lead_run(None, net, None, 1, 20);
    let clean = lead_run(None, None, None, 1, 20);
    for (ma, mb) in fiction.series.iter().zip(&clean.series) {
        assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
    }
    assert!(fiction.net.as_ref().unwrap().capped > 0, "caps are still counted without a plan");
    assert!(fiction.faults.is_none());
}
