//! §Perf regression: the engine's round loop performs ZERO heap
//! allocations in steady state, on the dense (quantize) and both sparse
//! (top-k, rand-k) paths.
//!
//! Methodology: a counting global allocator tallies every `alloc` /
//! `realloc`. Two runs that differ only in round count must allocate the
//! *same* total — setup, warm-up (lazy buffer growth in the first
//! round(s)), and the two metric observations (round 0 + final) are
//! identical between them, so any difference is per-round allocation:
//!
//! `allocs(R2 rounds) − allocs(R1 rounds) = (R2 − R1) · per_round = 0`.
//!
//! This covers the whole loop — mini-batch draws, the fused
//! gradient→send→compress produce phase (pool dispatch included),
//! sparse-aware mixing, and the parallel apply — with `record_every`
//! large so no observation lands in the differential window (observed
//! rounds are a documented exception: metric passes allocate scratch).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use lead::algorithms::lead::Lead;
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::topk::TopK;
use lead::compress::Compressor;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::problems::quad::Quad;
use lead::topology::{MixingRule, Topology};

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

/// The allocation and decode counters are process-global, but the test
/// runner executes the `#[test]` fns in this binary concurrently —
/// serialize them so one test's differential window can never absorb
/// another's allocations.
static SERIAL: Mutex<()> = Mutex::new(());

struct Counting;

// SAFETY: delegates everything to `System`; only adds a counter.
unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: Counting = Counting;

const N_AGENTS: usize = 8;

/// Allocation count and (debug builds) dense-decode-rebuild count for one
/// engine run of `rounds` rounds. `trace` turns the §Observability
/// recorder on — its rings are pre-allocated at setup, so the zero-alloc
/// differential must hold either way.
fn counts_for(rounds: usize, threads: usize, comp: Box<dyn Compressor>, trace: bool) -> (usize, u64) {
    let d = 96;
    let mix = Topology::Ring.build(N_AGENTS, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig {
            eta: 0.05,
            threads,
            trace,
            // No observation falls inside the differential window.
            record_every: usize::MAX / 2,
            ..Default::default()
        },
        mix,
        std::sync::Arc::new(Quad::new(N_AGENTS, d, 7)),
    );
    let before = ALLOCS.load(Ordering::SeqCst);
    #[cfg(debug_assertions)]
    let decodes_before = lead::compress::CompressedMsg::dense_decode_count();
    let rec = e.run(Box::new(Lead::paper_default()), Some(comp), rounds);
    let total = ALLOCS.load(Ordering::SeqCst) - before;
    #[cfg(debug_assertions)]
    let decodes = lead::compress::CompressedMsg::dense_decode_count() - decodes_before;
    #[cfg(not(debug_assertions))]
    let decodes = 0u64;
    assert_eq!(rec.series.len(), 2, "only round 0 and the final round observed");
    (total, decodes)
}

fn assert_zero_steady_state(name: &str, make: fn() -> Box<dyn Compressor>) {
    let _serial = SERIAL.lock().unwrap();
    for threads in [1usize, 2] {
        // Throwaway run first so whole-process lazy init (thread-local
        // setup, allocator internals) cannot skew the differential.
        let _ = counts_for(3, threads, make(), false);
        let (short, _) = counts_for(5, threads, make(), false);
        let (long, _) = counts_for(45, threads, make(), false);
        assert_eq!(
            short, long,
            "{name} path allocates in steady state (threads={threads}): \
             {short} allocs for 5 rounds vs {long} for 45 — \
             {} per extra round",
            (long as f64 - short as f64) / 40.0
        );
    }
}

/// Dense path: 2-bit ∞-norm quantization. Every buffer (payload bits,
/// decoded values, mixes, gradients) must be reused after warm-up.
#[test]
fn dense_quantize_path_is_zero_alloc_in_steady_state() {
    assert_zero_steady_state("dense/quantize", || {
        Box::new(QuantizeP::new(2, PNorm::Inf, 512))
    });
}

/// Sparse path: top-k with the scratch-carrying `compress_into` fast path
/// (index buffer reuse, lazy dense decode) plus sparse scatter mixing and
/// sparse-own apply.
#[test]
fn sparse_topk_path_is_zero_alloc_in_steady_state() {
    assert_zero_steady_state("sparse/top-k", || Box::new(TopK::new(9)));
}

/// Sparse path: rand-k. Its `compress_into` reuses the `CodecScratch`
/// index buffer for the Floyd sample (`Rng::sample_indices_into`) and
/// sorts indices in place instead of re-sorting the sparse pair list, so
/// the zero-alloc guarantee covers all sparsifiers.
#[test]
fn sparse_randk_path_is_zero_alloc_in_steady_state() {
    assert_zero_steady_state("sparse/rand-k", || {
        Box::new(lead::compress::randk::RandK::new(9, true))
    });
}

/// Sparse-own contract (§Perf): the top-k/rand-k steady state never
/// rebuilds a dense decoded vector — LEAD consumes its own message
/// through `Inbox::own_view` straight from the sparse entries, so
/// `ensure_dense` runs **only** for the observed-round compression-error
/// pass. Here only the final round is observed, so a whole run rebuilds
/// exactly `n` messages regardless of round count; the dense quantize
/// path never has a stale message at all. Debug builds only (the counter
/// is compiled out in release).
#[cfg(debug_assertions)]
#[test]
fn sparse_own_steady_state_never_decodes_dense() {
    let _serial = SERIAL.lock().unwrap();
    let sparsifiers: [(&str, fn() -> Box<dyn Compressor>); 2] = [
        ("top-k", || Box::new(TopK::new(9))),
        ("rand-k", || Box::new(lead::compress::randk::RandK::new(9, true))),
    ];
    for (name, make) in sparsifiers {
        for threads in [1usize, 2] {
            let (_, short) = counts_for(5, threads, make(), false);
            let (_, long) = counts_for(45, threads, make(), false);
            assert_eq!(
                short, long,
                "{name} (threads={threads}): per-round dense own-decode detected"
            );
            assert_eq!(
                long, N_AGENTS as u64,
                "{name} (threads={threads}): expected exactly one decode per agent \
                 (final observed round), got {long}"
            );
        }
    }
    let (_, dense_decodes) = counts_for(5, 1, Box::new(QuantizeP::new(2, PNorm::Inf, 512)), false);
    assert_eq!(dense_decodes, 0, "dense codec messages are never stale");
}

/// §Observability contract: tracing preserves the zero-alloc steady
/// state. The recorder's per-lane rings and histogram are pre-allocated
/// in `Recorder::new` (setup, outside the differential window); a
/// steady-state round only overwrites ring slots and bumps atomics, so
/// the traced differential must be exactly as flat as the untraced one —
/// on both the dense and sparse message paths, with the pool dispatching
/// (threads = 2, traced wake/dispatch events live).
#[test]
fn traced_runs_preserve_zero_alloc_steady_state() {
    let _serial = SERIAL.lock().unwrap();
    let codecs: [(&str, fn() -> Box<dyn Compressor>); 2] = [
        ("dense/quantize", || Box::new(QuantizeP::new(2, PNorm::Inf, 512))),
        ("sparse/top-k", || Box::new(TopK::new(9))),
    ];
    for (name, make) in codecs {
        for threads in [1usize, 2] {
            let _ = counts_for(3, threads, make(), true);
            let (short, _) = counts_for(5, threads, make(), true);
            let (long, _) = counts_for(45, threads, make(), true);
            assert_eq!(
                short, long,
                "{name} path allocates in steady state with tracing on \
                 (threads={threads}): {short} allocs for 5 rounds vs {long} for 45 — \
                 {} per extra round",
                (long as f64 - short as f64) / 40.0
            );
        }
    }
}
