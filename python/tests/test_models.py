"""L2 correctness: closed-form gradients vs jax.grad; transformer step
sanity (shapes, finiteness, loss decreases under SGD)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, transformer

jax.config.update("jax_platform_name", "cpu")


def test_linreg_grad_matches_autodiff():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (20, 12), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (20,), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (12,), jnp.float32)
    lam = jnp.float32(0.1)
    want = jax.grad(lambda xx: model.linreg_loss(a, b, xx, lam)[0])(x)
    got = model.linreg_grad(a, b, x, lam)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_logreg_grad_matches_autodiff():
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(key, (50, 13), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(4), (50,), 0, 4)
    y = jax.nn.one_hot(labels, 4, dtype=jnp.float32)
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (13, 4), jnp.float32)
    lam = jnp.float32(1e-3)
    want = jax.grad(lambda ww: model.logreg_loss(x, y, ww, lam)[0])(w)
    got = model.logreg_grad(x, y, w, lam)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_mlp_grad_descends():
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    w1 = 0.05 * jax.random.normal(ks[0], (32, 16), jnp.float32)
    b1 = jnp.zeros((16,), jnp.float32)
    w2 = 0.05 * jax.random.normal(ks[1], (16, 4), jnp.float32)
    b2 = jnp.zeros((4,), jnp.float32)
    x = jax.random.uniform(ks[2], (32, 32), jnp.float32)
    y = jax.nn.one_hot(jax.random.randint(ks[3], (32,), 0, 4), 4,
                       dtype=jnp.float32)
    loss0, gw1, gb1, gw2, gb2 = model.mlp_grad(w1, b1, w2, b2, x, y)
    lr = 0.5
    loss1 = model.mlp_loss(w1 - lr * gw1, b1 - lr * gb1,
                           w2 - lr * gw2, b2 - lr * gb2, x, y)
    assert float(loss1) < float(loss0)


def test_transformer_shapes_and_descent():
    cfg = transformer.Config(vocab=64, d_model=32, n_layer=1, n_head=2,
                             d_ff=64, seq_len=16)
    params = transformer.init_params(cfg, jax.random.PRNGKey(7))
    specs = transformer.param_specs(cfg)
    assert len(params) == len(specs)
    for p, (_, s) in zip(params, specs):
        assert p.shape == tuple(s)
    tokens = jax.random.randint(jax.random.PRNGKey(8), (4, cfg.seq_len),
                                0, cfg.vocab)
    step = transformer.train_step(cfg)
    out = step(*params, tokens)
    loss0, grads = out[0], out[1:]
    assert np.isfinite(float(loss0))
    # ~ln(vocab) at init.
    assert abs(float(loss0) - np.log(cfg.vocab)) < 1.0
    # One SGD step decreases the loss on the same batch.
    new_params = [p - 0.5 * g for p, g in zip(params, grads)]
    loss1 = transformer.loss_fn(cfg, new_params, tokens)
    assert float(loss1) < float(loss0)


def test_transformer_causality():
    """Changing a future token must not affect earlier logits."""
    cfg = transformer.Config(vocab=32, d_model=16, n_layer=1, n_head=2,
                             d_ff=32, seq_len=8)
    params = transformer.init_params(cfg, jax.random.PRNGKey(9))
    t1 = jnp.array([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    t2 = t1.at[0, 7].set(9)
    l1 = transformer.forward(cfg, params, t1)
    l2 = transformer.forward(cfg, params, t2)
    np.testing.assert_allclose(np.asarray(l1[0, :7]), np.asarray(l2[0, :7]),
                               rtol=1e-5, atol=1e-6)
