"""L1 correctness: the Pallas quantization kernel vs the pure-jnp oracle,
swept over shapes/bits/norms with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quantize import quantize
from compile.kernels.ref import quantize_ref

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    block=st.sampled_from([16, 64, 512]),
    bits=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref(blocks, block, bits, seed):
    d = blocks * block
    key = jax.random.PRNGKey(seed)
    kx, ku = jax.random.split(key)
    x = jax.random.normal(kx, (d,), jnp.float32) * 3.0
    u = jax.random.uniform(ku, (d,), jnp.float32)
    got = quantize(x, u, bits=bits, block=block)
    want = quantize_ref(x, u, bits=bits, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(p=st.sampled_from([1.0, 2.0, 6.0]), seed=st.integers(0, 10_000))
def test_pallas_matches_ref_finite_p(p, seed):
    d = 256
    key = jax.random.PRNGKey(seed)
    kx, ku = jax.random.split(key)
    x = jax.random.normal(kx, (d,), jnp.float32)
    u = jax.random.uniform(ku, (d,), jnp.float32)
    got = quantize(x, u, bits=3, block=128, p=p)
    want = quantize_ref(x, u, bits=3, block=128, p=p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_zero_vector():
    d = 512
    z = jnp.zeros((d,), jnp.float32)
    u = jnp.full((d,), 0.9, jnp.float32)
    out = quantize(z, u, bits=2, block=512)
    assert np.all(np.asarray(out) == 0.0)


def test_unbiased_statistically():
    """E[Q(x)] = x (Theorem 3) via Monte-Carlo over the dither."""
    d, trials = 128, 3000
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (d,), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), trials)
    us = jax.vmap(lambda k: jax.random.uniform(k, (d,), jnp.float32))(keys)
    outs = jax.vmap(lambda u: quantize_ref(x, u, bits=2, block=128))(us)
    mean = np.asarray(jnp.mean(outs, axis=0))
    unit = float(jnp.max(jnp.abs(x))) / 2.0
    tol = 6.0 * unit / np.sqrt(12.0 * trials)
    np.testing.assert_allclose(mean, np.asarray(x), atol=tol)


def test_variance_bound():
    """E‖x − Q(x)‖² ≤ C‖x‖² with C = block/4^bits (Remark 7, p = ∞)."""
    d, block, bits, trials = 256, 64, 2, 500
    x = jax.random.normal(jax.random.PRNGKey(3), (d,), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(4), trials)
    us = jax.vmap(lambda k: jax.random.uniform(k, (d,), jnp.float32))(keys)
    outs = jax.vmap(lambda u: quantize_ref(x, u, bits=bits, block=block))(us)
    err = float(jnp.mean(jnp.sum((outs - x[None]) ** 2, axis=1)))
    c = block / 4.0 ** bits
    bound = c * float(jnp.sum(x * x))
    assert err <= bound * 1.1, (err, bound)


def test_inf_norm_dominates_fig5():
    """Appendix C / Fig. 5: relative error decreases as p grows."""
    d = 4096
    x = jax.random.normal(jax.random.PRNGKey(5), (d,), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(6), (d,), jnp.float32)
    errs = []
    for p in [1.0, 2.0, 6.0, None]:
        q = quantize_ref(x, u, bits=2, block=4096, p=p)
        errs.append(float(jnp.linalg.norm(q - x) / jnp.linalg.norm(x)))
    assert errs[0] > errs[1] > errs[2] > errs[3], errs


@pytest.mark.parametrize("bad_d", [100, 513])
def test_rejects_unpadded(bad_d):
    x = jnp.zeros((bad_d,), jnp.float32)
    u = jnp.zeros((bad_d,), jnp.float32)
    with pytest.raises(AssertionError):
        quantize(x, u, bits=2, block=512)
