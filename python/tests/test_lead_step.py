"""L1 correctness: fused LEAD local-step kernel vs the unfused oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.lead_step import lead_local_step
from compile.kernels.ref import lead_local_step_ref

jax.config.update("jax_platform_name", "cpu")


def _state(d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (d,), jnp.float32)
    g = jax.random.normal(ks[1], (d,), jnp.float32)
    dv = jax.random.normal(ks[2], (d,), jnp.float32) * 0.1
    h = x + 0.05 * jax.random.normal(ks[3], (d,), jnp.float32)
    u = jax.random.uniform(ks[4], (d,), jnp.float32)
    return x, g, dv, h, u


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([64, 512]),
    bits=st.integers(min_value=1, max_value=4),
    seed=st.integers(0, 2**31 - 1),
    eta=st.sampled_from([0.01, 0.1, 0.5]),
    alpha=st.sampled_from([0.1, 0.5, 1.0]),
)
def test_fused_matches_unfused(blocks, block, bits, seed, eta, alpha):
    d = blocks * block
    x, g, dv, h, u = _state(d, seed)
    eta_a = jnp.float32(eta)
    alpha_a = jnp.float32(alpha)
    y1, q1, h1 = lead_local_step(x, g, dv, h, u, eta_a, alpha_a,
                                 bits=bits, block=block)
    y2, q2, h2 = lead_local_step_ref(x, g, dv, h, u, eta, alpha,
                                     bits=bits, block=block)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-6, atol=1e-6)


def test_exact_state_tracking_limit():
    """As h → y the difference vanishes, q → 0, and h⁺ = h."""
    d = 512
    x, g, dv, _, u = _state(d, 7)
    y = x - 0.1 * g - 0.1 * dv
    y2, q, h2 = lead_local_step(x, g, dv, y, u, jnp.float32(0.1),
                                jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), atol=1e-7)
    assert np.allclose(np.asarray(q), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(y), atol=1e-7)
