"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal. Every kernel in this package must match its reference here
(pytest: python/tests/)."""

import jax.numpy as jnp


def quantize_ref(x, u, *, bits: int = 2, block: int = 512, p=None):
    """Blockwise p-norm b-bit stochastic quantization, vectorized jnp."""
    (d,) = x.shape
    assert d % block == 0
    xb = x.reshape(-1, block)
    ub = u.reshape(-1, block)
    if p is None:
        norm = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    else:
        norm = jnp.sum(jnp.abs(xb) ** p, axis=1, keepdims=True) ** (1.0 / p)
    scale = jnp.float32(2 ** (bits - 1))
    safe = jnp.maximum(norm, 1e-30)
    level = jnp.minimum(jnp.floor(scale * jnp.abs(xb) / safe + ub), scale)
    out = jnp.where(norm > 0, jnp.sign(xb) * (norm / scale) * level,
                    jnp.zeros_like(xb))
    return out.reshape(d)


def lead_local_step_ref(x, g, d, h, u, eta, alpha, *, bits: int = 2,
                        block: int = 512):
    """Composition of the unfused ops (the thing the fused kernel saves)."""
    y = x - eta * g - eta * d
    q = quantize_ref(y - h, u, bits=bits, block=block)
    h_new = h + alpha * q
    return y, q, h_new
