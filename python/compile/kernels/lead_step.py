"""Layer-1 Pallas kernel: the fused LEAD local step (Alg. 2 lines 8-10+14).

Per agent and round, the purely-local part of LEAD is a chain of
element-wise passes over four d-vectors:

    y  = x − η·g − η·d          (auxiliary variable)
    q  = Q(y − h)               (difference compression, blockwise q∞)
    h⁺ = (1−α)·h + α·(h + q)    (momentum state = h + α·q)

Unfused this is 3 kernel launches and ~9 HBM round-trips per element;
fused it is 4 reads (x, g, d, h) + 1 read (u) + 3 writes (y, q, h⁺) in a
single VMEM-resident pass — the arithmetic intensity is tiny, so the fusion
is worth ~2.6× on memory-bound hardware (see EXPERIMENTS.md §Perf for the
estimate method). The dual/primal updates (lines 16-17) need the *mixed*
neighbor payloads and stay in the Layer-3 coordinator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lead_step_kernel(x_ref, g_ref, d_ref, h_ref, u_ref, eta_ref, alpha_ref,
                      y_ref, q_ref, hn_ref, *, bits: int):
    x = x_ref[...]
    g = g_ref[...]
    dv = d_ref[...]
    h = h_ref[...]
    u = u_ref[...]
    eta = eta_ref[0]
    alpha = alpha_ref[0]

    y = x - eta * g - eta * dv
    diff = y - h

    # Inline blockwise q∞ quantization of the difference (one block per
    # grid cell, same layout as kernels/quantize.py).
    norm = jnp.max(jnp.abs(diff))
    scale = jnp.float32(2 ** (bits - 1))
    safe = jnp.maximum(norm, jnp.float32(1e-30))
    level = jnp.minimum(jnp.floor(scale * jnp.abs(diff) / safe + u), scale)
    q = jnp.where(norm > 0, jnp.sign(diff) * (norm / scale) * level,
                  jnp.zeros_like(diff))

    y_ref[...] = y
    q_ref[...] = q
    hn_ref[...] = h + alpha * q


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def lead_local_step(x, g, d, h, u, eta, alpha, *, bits: int = 2,
                    block: int = 512):
    """Fused LEAD local step over 1-D state vectors (dim % block == 0).

    Returns (y, q, h_new); `q` is the dequantized broadcast payload.
    """
    (dim,) = x.shape
    assert dim % block == 0, f"pad to a multiple of {block} (got {dim})"
    grid = (dim // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((dim,), jnp.float32)
    eta = jnp.reshape(eta.astype(jnp.float32), (1,))
    alpha = jnp.reshape(alpha.astype(jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_lead_step_kernel, bits=bits),
        out_shape=(out, out, out),
        grid=grid,
        in_specs=[vec, vec, vec, vec, vec, scalar, scalar],
        out_specs=(vec, vec, vec),
        interpret=True,
    )(x, g, d, h, u, eta, alpha)
