"""Layer-1 Pallas kernel: blockwise p-norm b-bit stochastic quantization.

The paper's compression operator (Eq. 14 / Theorem 3):

    Q_p(x) = (‖x‖_p · sign(x) · 2^{-(b-1)}) ⊙ ⌊ 2^{b-1}|x| / ‖x‖_p + u ⌋

applied independently to blocks of `block` elements (paper §5 uses 512).
The stochastic dither `u ~ U[0,1)^d` is passed in as an input so the
kernel is a pure function (determinism + AOT-compatible; the rust
coordinator owns randomness).

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid cell per block; the
(block,)-tile lives in VMEM, the ‖·‖∞ reduction and the dither/floor are
VPU element-wise ops — the kernel is memory-bound at 2 reads + 1 write per
element, so BlockSpec pipelining (double-buffered HBM↔VMEM) is the whole
performance story. `interpret=True` everywhere because the CPU PJRT plugin
cannot execute Mosaic custom-calls; on real TPUs drop the flag.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_block_kernel(x_ref, u_ref, o_ref, *, bits: int, p):
    """One grid cell = one quantization block resident in VMEM."""
    x = x_ref[...]
    u = u_ref[...]
    if p is None:  # ∞-norm (the paper's choice)
        norm = jnp.max(jnp.abs(x))
    else:
        norm = jnp.sum(jnp.abs(x) ** p) ** (1.0 / p)
    scale = jnp.float32(2 ** (bits - 1))
    # Guard the all-zero block: norm 0 ⇒ levels 0 ⇒ output 0.
    safe = jnp.maximum(norm, jnp.float32(1e-30))
    level = jnp.floor(scale * jnp.abs(x) / safe + u)
    level = jnp.minimum(level, scale)  # fp edge: |x| == norm, u → 1
    mag = (norm / scale) * level
    o_ref[...] = jnp.where(norm > 0, jnp.sign(x) * mag, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("bits", "block", "p"))
def quantize(x, u, *, bits: int = 2, block: int = 512, p=None):
    """Quantize a 1-D vector blockwise. `d` must be a multiple of `block`
    (callers pad with zeros — zero padding does not change block norms of
    the padded tail and dequantizes to exactly zero).
    """
    (d,) = x.shape
    assert d % block == 0, f"pad to a multiple of {block} (got {d})"
    grid = (d // block,)
    return pl.pallas_call(
        functools.partial(_quantize_block_kernel, bits=bits, p=p),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(x, u)
