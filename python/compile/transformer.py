"""Layer-2: byte-level GPT-style transformer LM for the end-to-end
decentralized-training example (the Fig. 4 "deep net" scaled up).

Pre-LN transformer with tied input/output embeddings. The whole train step
(fwd + bwd) lowers into ONE HLO artifact; parameters are separate inputs in
the canonical order given by `param_specs`, and the artifact returns
(loss, *grads) in the same order, so the rust ParamSpec mapping is purely
positional.
"""

import jax
import jax.numpy as jnp


class Config:
    def __init__(self, vocab=256, d_model=128, n_layer=2, n_head=4,
                 d_ff=512, seq_len=64):
        self.vocab = vocab
        self.d_model = d_model
        self.n_layer = n_layer
        self.n_head = n_head
        self.d_ff = d_ff
        self.seq_len = seq_len

    @classmethod
    def tiny(cls):
        """~0.45M params — the CPU-interpret CI budget."""
        return cls()

    @classmethod
    def small(cls):
        """~6M params — still CPU-feasible for a short demo run."""
        return cls(d_model=256, n_layer=4, n_head=8, d_ff=1024, seq_len=128)


def param_specs(cfg: Config):
    """Canonical (name, shape) list — the contract with rust's ParamSpec."""
    specs = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq_len, cfg.d_model)),
    ]
    for l in range(cfg.n_layer):
        specs += [
            (f"l{l}.ln1_scale", (cfg.d_model,)),
            (f"l{l}.ln1_bias", (cfg.d_model,)),
            (f"l{l}.attn_qkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{l}.attn_out", (cfg.d_model, cfg.d_model)),
            (f"l{l}.ln2_scale", (cfg.d_model,)),
            (f"l{l}.ln2_bias", (cfg.d_model,)),
            (f"l{l}.ff_in", (cfg.d_model, cfg.d_ff)),
            (f"l{l}.ff_out", (cfg.d_ff, cfg.d_model)),
        ]
    specs += [("lnf_scale", (cfg.d_model,)), ("lnf_bias", (cfg.d_model,))]
    return specs


def init_params(cfg: Config, key):
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("_bias"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in))
    return params


def _layernorm(x, scale, bias):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) / jnp.sqrt(v + 1e-5) * scale + bias


def forward(cfg: Config, params, tokens):
    """tokens: (B, T) int32 → logits (B, T, vocab)."""
    it = iter(params)
    embed = next(it)
    pos = next(it)
    b, t = tokens.shape
    h = embed[tokens] + pos[None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.float32(-1e9)
    for _ in range(cfg.n_layer):
        ln1_s, ln1_b = next(it), next(it)
        qkv_w, out_w = next(it), next(it)
        ln2_s, ln2_b = next(it), next(it)
        ff_in, ff_out = next(it), next(it)

        x = _layernorm(h, ln1_s, ln1_b)
        qkv = x @ qkv_w  # (B, T, 3D)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = cfg.d_model // cfg.n_head

        def heads(z):
            return z.reshape(b, t, cfg.n_head, hd).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        z = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        h = h + z @ out_w

        x = _layernorm(h, ln2_s, ln2_b)
        h = h + jax.nn.gelu(x @ ff_in) @ ff_out

    lnf_s, lnf_b = next(it), next(it)
    h = _layernorm(h, lnf_s, lnf_b)
    return h @ embed.T  # tied output head


def loss_fn(cfg: Config, params, tokens):
    """Next-token cross-entropy over (B, T)."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def train_step(cfg: Config):
    """Returns f(*params, tokens) -> (loss, *grads) for AOT lowering."""
    n = len(param_specs(cfg))

    def f(*args):
        params = list(args[:n])
        tokens = args[n]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, ps, tokens))(params)
        return (loss, *grads)

    return f
