"""AOT pipeline: lower every Layer-2 graph to HLO *text* + a manifest.

HLO text — not serialized HloModuleProto — is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(the version the rust `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, transformer


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_entry(name, s):
    return {"name": name, "shape": list(s.shape),
            "dtype": str(s.dtype.name if hasattr(s.dtype, "name") else s.dtype)}


def lower(out_dir, manifest, name, fn, inputs, outputs_doc, extra=None):
    """Lower fn at the given example inputs and record a manifest entry."""
    lowered = jax.jit(fn).lower(*[s for _, s in inputs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "file": fname,
        "inputs": [input_entry(n, s) for n, s in inputs],
        "outputs": outputs_doc,
    }
    if extra:
        entry.update(extra)
    manifest["artifacts"].append(entry)
    print(f"  {name}: {len(text)} chars, {len(inputs)} inputs")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}

    # ---- linear regression (paper shapes: A ∈ R^{200×200}) --------------
    lower(args.out, manifest, "linreg_grad",
          model.linreg_grad,
          [("a", spec((200, 200))), ("b", spec((200,))),
           ("x", spec((200,))), ("lam", spec(()))],
          [{"name": "grad", "shape": [200]}])
    lower(args.out, manifest, "linreg_loss",
          model.linreg_loss,
          [("a", spec((200, 200))), ("b", spec((200,))),
           ("x", spec((200,))), ("lam", spec(()))],
          [{"name": "loss", "shape": []}])

    # ---- logistic regression (MNIST-like: 1000 samples/agent, 784×10) ---
    lower(args.out, manifest, "logreg_grad",
          model.logreg_grad,
          [("x", spec((1000, 784))), ("y", spec((1000, 10))),
           ("w", spec((784, 10))), ("lam", spec(()))],
          [{"name": "grad", "shape": [784, 10]}])
    lower(args.out, manifest, "logreg_loss",
          model.logreg_loss,
          [("x", spec((1000, 784))), ("y", spec((1000, 10))),
           ("w", spec((784, 10))), ("lam", spec(()))],
          [{"name": "loss", "shape": []}])

    # ---- MLP (Fig. 4 deep-net substitute; CIFAR-shaped 3072→256→10) -----
    lower(args.out, manifest, "mlp_grad",
          model.mlp_grad,
          [("w1", spec((3072, 256))), ("b1", spec((256,))),
           ("w2", spec((256, 10))), ("b2", spec((10,))),
           ("x", spec((64, 3072))), ("y", spec((64, 10)))],
          [{"name": "loss", "shape": []},
           {"name": "gw1", "shape": [3072, 256]}, {"name": "gb1", "shape": [256]},
           {"name": "gw2", "shape": [256, 10]}, {"name": "gb2", "shape": [10]}],
          extra={"param_inputs": [0, 1, 2, 3], "data_inputs": [4, 5]})
    lower(args.out, manifest, "mlp_loss",
          model.mlp_loss_t,
          [("w1", spec((3072, 256))), ("b1", spec((256,))),
           ("w2", spec((256, 10))), ("b2", spec((10,))),
           ("x", spec((64, 3072))), ("y", spec((64, 10)))],
          [{"name": "loss", "shape": []}],
          extra={"param_inputs": [0, 1, 2, 3], "data_inputs": [4, 5]})

    # ---- Layer-1 Pallas kernels wrapped as standalone artifacts ---------
    lower(args.out, manifest, "quantize_2bit_4096",
          model.quantize_fn,
          [("x", spec((4096,))), ("u", spec((4096,)))],
          [{"name": "values", "shape": [4096]}])
    lower(args.out, manifest, "lead_step_4096",
          model.lead_step_fn,
          [("x", spec((4096,))), ("g", spec((4096,))), ("d", spec((4096,))),
           ("h", spec((4096,))), ("u", spec((4096,))),
           ("eta", spec(())), ("alpha", spec(()))],
          [{"name": "y", "shape": [4096]}, {"name": "q", "shape": [4096]},
           {"name": "h_new", "shape": [4096]}])

    # ---- transformer train step (tiny config) ----------------------------
    cfg = transformer.Config.tiny()
    specs = transformer.param_specs(cfg)
    t_inputs = [(n, spec(s)) for n, s in specs]
    t_inputs.append(("tokens", spec((8, cfg.seq_len), jnp.int32)))
    t_outputs = [{"name": "loss", "shape": []}] + [
        {"name": f"g:{n}", "shape": list(s)} for n, s in specs
    ]
    lower(args.out, manifest, "transformer_tiny_step",
          transformer.train_step(cfg), t_inputs, t_outputs,
          extra={
              "param_inputs": list(range(len(specs))),
              "data_inputs": [len(specs)],
              "config": {"vocab": cfg.vocab, "d_model": cfg.d_model,
                         "n_layer": cfg.n_layer, "n_head": cfg.n_head,
                         "d_ff": cfg.d_ff, "seq_len": cfg.seq_len},
          })

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts -> {args.out}")


if __name__ == "__main__":
    main()
