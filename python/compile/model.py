"""Layer-2: JAX compute graphs for the paper's workloads.

Each function here is lowered ONCE by aot.py into an HLO-text artifact the
rust coordinator executes through PJRT. Convex problems (linreg / logreg)
have native rust oracles too; the PJRT path is cross-checked against them
in rust/tests/runtime_pjrt.rs to 1e-4.

Conventions:
- all tensors f32; labels are pre-one-hotted (B, K) so artifacts take only
  float inputs (no int handling across the FFI);
- parameters are separate tensor inputs, never flattened here — the rust
  runtime's ParamSpec does flat-vector ↔ tensor mapping;
- every *_grad function returns gradients in the same order as its
  parameter inputs.
"""

import jax
import jax.numpy as jnp

from .kernels.lead_step import lead_local_step
from .kernels.quantize import quantize


# --------------------------------------------------------------------------
# Linear regression (paper §5, Fig. 1):  f_i(x) = ‖Ax − b‖² + λ‖x‖²
# --------------------------------------------------------------------------

def linreg_loss(a, b, x, lam):
    r = a @ x - b
    return (jnp.sum(r * r) + lam * jnp.sum(x * x),)


def linreg_grad(a, b, x, lam):
    """∇f(x) = 2Aᵀ(Ax − b) + 2λx."""
    r = a @ x - b
    return (2.0 * (a.T @ r) + 2.0 * lam * x,)


# --------------------------------------------------------------------------
# Multinomial logistic regression (Figs. 2-3, 8-9):
#   f(w) = mean CE(softmax(xᵀw), y) + (λ/2)‖w‖²
# --------------------------------------------------------------------------

def logreg_loss(x, y_onehot, w, lam):
    logits = x @ w
    lse = jax.nn.logsumexp(logits, axis=1)
    ce = jnp.mean(lse - jnp.sum(logits * y_onehot, axis=1))
    return (ce + 0.5 * lam * jnp.sum(w * w),)


def logreg_grad(x, y_onehot, w, lam):
    """Closed-form softmax-CE gradient: (1/B)Xᵀ(softmax − Y) + λw."""
    p = jax.nn.softmax(x @ w, axis=1)
    return ((x.T @ (p - y_onehot)) / x.shape[0] + lam * w,)


# --------------------------------------------------------------------------
# MLP classifier — the Fig. 4 "deep net" substitute (CIFAR-shaped inputs).
# --------------------------------------------------------------------------

def mlp_loss(w1, b1, w2, b2, x, y_onehot):
    h = jax.nn.relu(x @ w1 + b1)
    logits = h @ w2 + b2
    lse = jax.nn.logsumexp(logits, axis=1)
    return jnp.mean(lse - jnp.sum(logits * y_onehot, axis=1))


def mlp_loss_t(w1, b1, w2, b2, x, y_onehot):
    return (mlp_loss(w1, b1, w2, b2, x, y_onehot),)


def mlp_grad(w1, b1, w2, b2, x, y_onehot):
    """Loss + parameter gradients, one artifact (fwd+bwd fused by XLA)."""
    loss, grads = jax.value_and_grad(mlp_loss, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, x, y_onehot)
    return (loss, *grads)


# --------------------------------------------------------------------------
# LEAD local step and standalone quantization (Layer-1 kernels in an HLO
# wrapper so the rust hot path can invoke them through PJRT).
# --------------------------------------------------------------------------

def lead_step_fn(x, g, d, h, u, eta, alpha):
    """Fused LEAD local step, bits=2 / block=512 (the paper's setting)."""
    return lead_local_step(x, g, d, h, u, eta, alpha, bits=2, block=512)


def quantize_fn(x, u):
    """Standalone 2-bit q∞ quantization, block 512."""
    return (quantize(x, u, bits=2, block=512),)
