//! Appendix C (Figs. 5-6): compression-operator study — p-norm comparison
//! and q∞ vs top-k vs random-k under equal bit budgets.
//!
//!     cargo run --release --example compression_analysis
fn main() {
    let out = Some(std::path::Path::new("results"));
    lead::experiments::fig5(out).expect("fig5");
    lead::experiments::fig6(out).expect("fig6");
}
