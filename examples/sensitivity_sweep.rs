//! Fig. 7 / Appendix D.1: LEAD's robustness to (α, γ) — the paper's
//! "minor tuning effort" claim, measured as rounds-to-1e-6 on each cell.
//!
//!     cargo run --release --example sensitivity_sweep
fn main() {
    let rows = lead::experiments::fig7(Some(std::path::Path::new("results")), 1500).expect("fig7");
    let ok = rows.iter().filter(|r| r.2.is_some()).count();
    println!("\n{ok}/{} (α, γ) cells converged to 1e-6", rows.len());
}
