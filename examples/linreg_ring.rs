//! Fig. 1 end-to-end: all six paper algorithms on the linear-regression
//! ring, printing the four panels' final numbers and writing CSVs.
//!
//!     cargo run --release --example linreg_ring [-- --rounds 1500]
fn main() {
    let rounds = std::env::args()
        .skip_while(|a| a != "--rounds")
        .nth(1)
        .and_then(|r| r.parse().ok())
        .unwrap_or(1500);
    lead::experiments::fig1(Some(std::path::Path::new("results")), rounds).expect("fig1");
    println!("\nCSV series written to results/fig1_linreg_*.csv");
}
