//! End-to-end driver: decentralized training of a GPT-style byte-level
//! transformer LM with LEAD + 2-bit quantized gossip — all three layers
//! composing on a real workload:
//!
//!   L1  Pallas quantization semantics (same operator as the rust codec,
//!       verified equivalent in rust/tests/runtime_pjrt.rs)
//!   L2  the transformer fwd+bwd lowered once to artifacts/transformer_
//!       tiny_step.hlo.txt (python never runs here)
//!   L3  this rust process: 8 agents on a ring, LEAD with 2-bit q-inf
//!       difference compression, exact wire-bit accounting
//!
//!     make artifacts && cargo run --release --example train_transformer
//!       [-- --rounds 300] [--agents 8] [--algo lead|dgd|choco]
//!
//! Each agent holds a *different* synthetic byte corpus (heterogeneous by
//! construction), so plain DGD-style averaging is biased while LEAD's dual
//! correction still drives consensus — run with `--algo dgd` to see the
//! contrast. The loss curve is logged to results/transformer_loss.csv and
//! recorded in EXPERIMENTS.md.

use lead::compress::quantize::QuantizeP;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::problems::neural::TransformerProblem;
use lead::runtime::Manifest;
use lead::topology::{MixingRule, Topology};

fn flag(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let rounds: usize = flag("--rounds").and_then(|v| v.parse().ok()).unwrap_or(300);
    let agents: usize = flag("--agents").and_then(|v| v.parse().ok()).unwrap_or(8);
    let algo_name = flag("--algo").unwrap_or_else(|| "lead".into());

    let manifest = Manifest::load("artifacts")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let problem = TransformerProblem::new(&manifest, agents, 1 << 15, 7)?;
    let params = problem.param_count();
    println!(
        "decentralized transformer LM: {agents} agents (ring), {:.2}M params, {rounds} rounds",
        params as f64 / 1e6
    );
    println!("algorithm: {algo_name}  compression: 2-bit q-inf/512 (~10.4x fewer bits than f32)");

    let mix = Topology::Ring.build(agents, MixingRule::UniformNeighbors);
    let algo = lead::config::build_algo(&algo_name, 1.0, 0.5)
        .ok_or_else(|| anyhow::anyhow!("unknown algo {algo_name:?}"))?;
    let compressed = algo.spec().compressed;
    let mut engine = Engine::new(
        EngineConfig {
            eta: 0.05,
            batch_size: Some(8), // token batches are sampled inside the problem
            record_every: (rounds / 30).max(1),
            ..Default::default()
        },
        mix,
        std::sync::Arc::new(problem),
    );
    let t = std::time::Instant::now();
    let rec = engine.run(
        algo,
        if compressed { Some(Box::new(QuantizeP::paper_default())) } else { None },
        rounds,
    );
    let secs = t.elapsed().as_secs_f64();

    println!("\nround   loss     consensus    bits/agent");
    for m in &rec.series {
        println!(
            "{:>5}   {:<8.4} {:<12.3e} {:.3e}",
            m.round, m.loss, m.consensus, m.bits_per_agent
        );
    }
    let first = rec.series.first().unwrap().loss;
    let last = rec.last().loss;
    println!(
        "\nloss {first:.4} -> {last:.4} over {rounds} rounds  ({secs:.2}s, {:.2} rounds/s)",
        rounds as f64 / secs,
    );
    println!(
        "communication: {:.2} MB/agent compressed (vs {:.2} MB/agent raw f32)",
        rec.last().bits_per_agent / 8e6,
        (rounds * params * 32) as f64 / 8e6
    );
    std::fs::create_dir_all("results").ok();
    rec.write_csv(std::path::Path::new("results"), "transformer_loss")?;
    println!("series written to results/transformer_loss.csv");
    anyhow::ensure!(last < first, "training did not reduce loss");
    Ok(())
}
