//! Figs. 2-3: logistic regression on the heterogeneous (sort-by-label)
//! split — the regime where DGD-type compressed baselines struggle and
//! LEAD's gradient correction matters (paper §5).
//!
//!     cargo run --release --example logreg_heterogeneous
use lead::problems::DataSplit;
fn main() {
    let out = Some(std::path::Path::new("results"));
    println!("=== full-batch (Fig. 2) ===");
    lead::experiments::fig_logreg(DataSplit::Heterogeneous, false, out, 400, 4000).expect("fig2");
    println!("\n=== mini-batch 512 (Fig. 3) ===");
    lead::experiments::fig_logreg(DataSplit::Heterogeneous, true, out, 400, 4000).expect("fig3");
}
