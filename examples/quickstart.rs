//! Quickstart: LEAD with 2-bit ∞-norm quantization on an 8-agent ring.
//!
//!     cargo run --release --example quickstart
//!
//! Reproduces the paper's headline in ~1 second: linear convergence to the
//! exact optimum under 2-bit communication, >10× fewer bits than the
//! uncompressed baseline.
use lead::algorithms::lead::Lead;
use lead::algorithms::nids::Nids;
use lead::compress::quantize::QuantizeP;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::problems::linreg::LinReg;
use lead::topology::{MixingRule, Topology};

fn main() {
    // 8 machines in a ring, uniform mixing weight 1/3 (paper §5).
    let topo = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    println!("topology: ring, β={:.3}, κ_g={:.2}", topo.beta(), topo.kappa_g());

    // The paper's linear-regression workload: A_i ∈ R^{200×200}, λ=0.1.
    let make_problem = || std::sync::Arc::new(LinReg::synthetic(8, 200, 0.1, 42));

    // LEAD, paper defaults (η=0.1, γ=1.0, α=0.5), 2-bit q∞ / block 512.
    let mut engine = Engine::new(EngineConfig::default(), topo.clone(), make_problem());
    let rec = engine.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::paper_default())),
        800,
    );

    // Uncompressed NIDS for comparison.
    let mut engine2 = Engine::new(EngineConfig::default(), topo, make_problem());
    let nids = engine2.run(Box::new(Nids::new()), None, 800);

    println!("\nround    LEAD+2bit dist(x*)    NIDS dist(x*)");
    for (a, b) in rec.series.iter().zip(&nids.series).step_by(10) {
        println!("{:>5}    {:>18.3e}    {:>13.3e}", a.round, a.dist_opt, b.dist_opt);
    }
    let tol = 1e-6;
    println!(
        "\nbits/agent to reach {tol:.0e}:  LEAD {:.2e}   NIDS {:.2e}  ({:.1}x saving)",
        rec.bits_to_tol(tol).unwrap(),
        nids.bits_to_tol(tol).unwrap(),
        nids.bits_to_tol(tol).unwrap() / rec.bits_to_tol(tol).unwrap()
    );
}
